"""Chaos scenario engine: injectors, JSONL traces, deterministic replay."""
import numpy as np
import pytest

from repro.configs.base import MeCeFOConfig
from repro.ft.controller import FTController
from repro.ft.events import (
    FAIL,
    NET_DEGRADE,
    NODE_HEAL,
    RANK_REJOIN,
    RECOVER,
    STRAGGLE,
    FailureEvent,
)
from repro.ft.failures import SCENARIOS, ChaosEngine, FailureScenario
from repro.ft.injectors import (
    CHAOS_PRESETS,
    CorrelatedDomainInjector,
    DomainOutageWithHealInjector,
    NetworkDegradationInjector,
    PoissonCrashInjector,
    ScheduledInjector,
    StragglerInjector,
    chaos_preset,
)
from repro.ft.trace import (
    TraceRecorder,
    load_trace,
    replay_engine,
    verify_replay,
)
from tests.conftest import TINY_DENSE

FAST = FailureScenario("fast", fail_interval_s=10.0, recover_time_s=30.0)


def _kitchen_sink_engine(seed=0, recorder=None):
    injectors = [
        PoissonCrashInjector(FAST),
        CorrelatedDomainInjector(50.0, 30.0, domain="stage"),
        StragglerInjector(20.0, 10.0, slow_factor=8.0),
        NetworkDegradationInjector(30.0, 10.0, inflation=3.0),
    ]
    return ChaosEngine(4, 4, 1.0, injectors, seed=seed, recorder=recorder)


def _drive(engine, steps, controller=None):
    """Run the engine; optionally accumulate controller accounting."""
    for step in range(steps):
        outcome = engine.step(step)
        if controller is not None:
            controller.apply_chaos(outcome)
    return engine


def _controller():
    return FTController(
        cfg=TINY_DENSE, mecefo=MeCeFOConfig(mode="dynamic"),
        n_dp=4, n_stages=4, global_batch=8,
    )


# ---------------------------------------------------------------------------
# event / trace serialization
# ---------------------------------------------------------------------------


def test_event_json_roundtrip():
    for ev in (
        FailureEvent(3, FAIL, (1, 2), duration_steps=30, source="poisson"),
        FailureEvent(5, STRAGGLE, (0, 0), duration_steps=10, magnitude=8.0),
        FailureEvent(7, NET_DEGRADE, None, duration_steps=4, magnitude=3.0),
        FailureEvent(9, RECOVER, (1, 2)),
    ):
        assert FailureEvent.from_json(ev.to_json()) == ev


def test_unknown_event_kind_rejected():
    with pytest.raises(ValueError):
        FailureEvent(0, "meteor-strike", (0, 0))


def test_trace_header_footer_roundtrip(tmp_path):
    path = tmp_path / "t.jsonl"
    eng = _kitchen_sink_engine(seed=3, recorder=TraceRecorder(path))
    _drive(eng, 50)
    eng.recorder.close(total_steps=50, accounting={"n_failovers": 12})
    trace = load_trace(path)
    assert trace.header.n_dp == 4 and trace.header.n_stages == 4
    assert trace.header.seed == 3
    assert len(trace.header.injectors) == 4
    assert trace.footer.total_steps == 50
    assert trace.footer.accounting["n_failovers"] == 12
    assert trace.footer.n_events == len(trace.events)


# ---------------------------------------------------------------------------
# deterministic replay (the CI-enforced property)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_record_replay_bit_exact_twice(tmp_path):
    """Record a trace; replay it twice; event streams and accounting match."""
    path = tmp_path / "chaos.jsonl"
    rec_ctl = _controller()
    eng = _kitchen_sink_engine(seed=11, recorder=TraceRecorder(path))
    _drive(eng, 200, rec_ctl)
    eng.recorder.close(total_steps=200,
                       accounting=rec_ctl.accounting.as_dict())
    assert rec_ctl.accounting.n_failovers > 0  # scenario actually fired
    trace = load_trace(path)

    streams, accountings = [], []
    for _ in range(2):
        ctl = _controller()
        replayed = _drive(replay_engine(trace), 200, ctl)
        assert not verify_replay(trace, replayed,
                                 accounting=ctl.accounting.as_dict())
        streams.append(list(replayed.events))
        accountings.append(ctl.accounting.as_dict())
    assert streams[0] == streams[1] == trace.events
    assert accountings[0] == accountings[1] == trace.footer.accounting


def test_same_seed_same_trace():
    """Engine determinism without a trace file: same seed, same events."""
    a = _drive(_kitchen_sink_engine(seed=5), 150).events
    b = _drive(_kitchen_sink_engine(seed=5), 150).events
    assert a == b
    c = _drive(_kitchen_sink_engine(seed=6), 150).events
    assert a != c  # different seed actually changes the sample path


def test_verify_replay_catches_divergence(tmp_path):
    path = tmp_path / "t.jsonl"
    eng = _kitchen_sink_engine(seed=2, recorder=TraceRecorder(path))
    _drive(eng, 100)
    eng.recorder.close(total_steps=100)
    trace = load_trace(path)
    diverged = _drive(replay_engine(trace), 99)  # one step short
    if len(trace.events) != len(diverged.events):
        assert verify_replay(trace, diverged)


@pytest.mark.chaos
def test_golden_trace_replays_bit_exactly():
    """The committed golden trace reproduces its events AND accounting."""
    from pathlib import Path

    from repro.configs.base import get_config, reduced

    golden = Path(__file__).parent / "data" / "golden_trace.jsonl"
    trace = load_trace(golden)
    assert trace.footer is not None, "golden trace missing footer"
    cfg = reduced(get_config("llama-350m"), dtype="float32")
    ctl = FTController(
        cfg=cfg, mecefo=MeCeFOConfig(mode="dynamic"),
        n_dp=trace.header.n_dp, n_stages=trace.header.n_stages,
        global_batch=8,
    )
    engine = _drive(replay_engine(trace), trace.footer.total_steps, ctl)
    problems = verify_replay(trace, engine,
                             accounting=ctl.accounting.as_dict())
    assert not problems, problems


# ---------------------------------------------------------------------------
# injectors
# ---------------------------------------------------------------------------


def test_correlated_stage_outage_kills_whole_column():
    eng = ChaosEngine(
        4, 4, 1.0,
        [CorrelatedDomainInjector(2.0, 1000.0, domain="stage")], seed=0,
    )
    hit = False
    for step in range(50):
        plan = eng.step(step).plan
        for s in range(4):
            if all((r, s) in plan.failed for r in range(4)):
                hit = True
        if hit:
            break
    assert hit, "no full stage column ever failed"


def test_correlated_dp_outage_drops_rank():
    eng = ChaosEngine(
        4, 4, 1.0, [CorrelatedDomainInjector(2.0, 1000.0, domain="dp")], seed=0,
    )
    dropped = set()
    for step in range(50):
        dropped |= eng.step(step).plan.dropped_ranks()
    assert dropped, "dp-domain outage never dropped a whole rank"


def test_straggler_feeds_controller_detection():
    eng = ChaosEngine(
        2, 2, 1.0, [StragglerInjector(1.0, 100.0, slow_factor=10.0)], seed=0,
    )
    ctl = FTController(
        cfg=TINY_DENSE, mecefo=MeCeFOConfig(mode="dynamic"),
        n_dp=2, n_stages=2, global_batch=4,
    )
    flagged = set()
    for step in range(20):
        outcome = eng.step(step)
        _, slow = ctl.apply_chaos(outcome)
        if slow:
            # slow devices are folded into the active NDB plan immediately
            assert slow <= set(ctl.plan.failed)
        flagged |= slow
    assert flagged, "straggler never flagged by the controller"


def test_straggler_sticky_revictimizes_same_device():
    # duration > interval so episodes overlap: a sticky straggler must not
    # migrate to a new device while the victim is still straggling
    inj = StragglerInjector(2.0, 5.0, slow_factor=8.0, sticky=True)
    eng = ChaosEngine(4, 4, 1.0, [inj], seed=1)
    victims = {
        ev.device
        for step in range(200)
        for ev in eng.step(step).events
        if ev.kind == STRAGGLE
    }
    assert len(victims) == 1, f"sticky straggler hit {victims}"


def test_network_degradation_inflates_recovery_traffic():
    sched = ScheduledInjector([
        FailureEvent(0, NET_DEGRADE, None, duration_steps=100, magnitude=3.0),
        FailureEvent(1, FAIL, (0, 1), duration_steps=5),
    ])
    eng = ChaosEngine(2, 2, 1.0, [sched], seed=0)
    ctl = FTController(
        cfg=TINY_DENSE, mecefo=MeCeFOConfig(mode="dynamic"),
        n_dp=2, n_stages=2, global_batch=4,
    )
    eng.step(0)
    outcome = eng.step(1)
    assert outcome.net_inflation == 3.0
    ctl.apply_chaos(outcome)
    assert ctl.accounting.peer_fetch_bytes == 3 * ctl.stage_param_bytes()


def test_network_restores_after_duration():
    sched = ScheduledInjector([
        FailureEvent(0, NET_DEGRADE, None, duration_steps=3, magnitude=2.0),
    ])
    eng = ChaosEngine(2, 2, 1.0, [sched], seed=0)
    inflations = [eng.step(s).net_inflation for s in range(6)]
    assert inflations[0] == 2.0 and inflations[2] == 2.0
    assert inflations[3] == 1.0
    kinds = [e.kind for e in eng.events]
    assert "net_restore" in kinds


def test_failed_device_cannot_straggle():
    sched = ScheduledInjector([
        FailureEvent(0, STRAGGLE, (0, 0), duration_steps=50, magnitude=8.0),
        FailureEvent(2, FAIL, (0, 0), duration_steps=5),
    ])
    eng = ChaosEngine(2, 2, 1.0, [sched], seed=0)
    eng.step(0)
    assert eng.state.slowdown((0, 0)) == 8.0
    out = eng.step(2)
    assert (0, 0) in out.plan.failed
    assert (0, 0) not in out.device_times  # down, not slow
    assert eng.state.slowdown((0, 0)) == 1.0


def test_scheduled_injector_applies_past_events_with_original_step():
    eng = ChaosEngine(2, 2, 1.0, seed=0)
    eng.inject(0, (0, 1), down_steps=5)
    assert (0, 1) in eng.step(1).plan.failed
    assert (0, 1) in eng.step(4).plan.failed
    assert (0, 1) not in eng.step(5).plan.failed  # until = 0 + 5
    assert [e.kind for e in eng.events] == ["fail", "recover"]


def test_chaos_presets_build():
    for name in CHAOS_PRESETS:
        injs = chaos_preset(name, SCENARIOS["high"])
        assert injs, name
    with pytest.raises(KeyError):
        chaos_preset("nope")


def test_overlapping_injectors_never_double_fail():
    """Two crash injectors racing on the same grid: one fail per device."""
    eng = ChaosEngine(
        2, 2, 1.0,
        [PoissonCrashInjector(FAST), PoissonCrashInjector(FAST)],
        seed=0,
    )
    for step in range(300):
        eng.step(step)
    # between a fail and its recover there is never another fail for the dev
    open_failures = set()
    for ev in eng.events:
        if ev.kind == FAIL:
            assert ev.device not in open_failures, ev
            open_failures.add(ev.device)
        elif ev.kind == RECOVER:
            open_failures.discard(ev.device)


# ---------------------------------------------------------------------------
# elastic DP: drop -> heal -> rejoin
# ---------------------------------------------------------------------------


def _schedule_domain_loss(eng, rank, fail_step, heal_step, transfer=2,
                          n_stages=4):
    for s in range(n_stages):
        eng.schedule(
            FailureEvent(fail_step, FAIL, (rank, s), duration_steps=10**9)
        )
        eng.schedule(
            FailureEvent(heal_step, NODE_HEAL, (rank, s),
                         duration_steps=transfer)
        )


def test_elastic_drop_heal_rejoin_restores_dp_size():
    eng = ChaosEngine(4, 4, 1.0, seed=0, elastic=True)
    _schedule_domain_loss(eng, rank=1, fail_step=2, heal_step=6, transfer=2)
    ctl = _controller()
    sizes = []
    for step in range(12):
        outcome = eng.step(step)
        ctl.apply_chaos(outcome)
        sizes.append(outcome.plan.dp_size())
        keep, w = plan_to_masks_for(ctl.plan)
        assert w.sum() == 8.0  # global batch preserved at every step
    assert sizes[1] == 4 and min(sizes) == 3 and sizes[-1] == 4
    assert ctl.plan.is_healthy()
    acc = ctl.accounting
    assert acc.n_rank_drops == 1 and acc.n_rejoins == 1
    # rejoin streams a FULL pipeline's state, not one stage
    assert acc.peer_fetch_bytes == 4 * ctl.stage_param_bytes()
    assert acc.n_failovers == 0 and acc.n_recoveries == 0
    kinds = [e.kind for e in eng.events]
    assert kinds.count(RANK_REJOIN) == 1 and kinds.count(NODE_HEAL) == 4
    rj = next(e for e in eng.events if e.kind == RANK_REJOIN)
    assert rj.rank == 1 and rj.device is None


def plan_to_masks_for(plan):
    from repro.core.ndb import plan_to_masks

    return plan_to_masks(plan, TINY_DENSE, 8)


def test_elastic_resize_emits_reshard_plan():
    eng = ChaosEngine(4, 4, 1.0, seed=0, elastic=True)
    _schedule_domain_loss(eng, rank=2, fail_step=1, heal_step=5, transfer=1)
    ctl = _controller()
    ctl.apply_chaos(eng.step(0))
    assert ctl.last_reshard is None
    ctl.apply_chaos(eng.step(1))
    rp = ctl.last_reshard
    assert rp is not None and rp.dropped == (2,) and rp.rejoined == ()
    assert rp.new_active == (0, 1, 3) and rp.dp_size == 3
    assert sum(rp.shares.values()) == 8  # batch fully redistributed
    assert rp.transfer_bytes == 0  # drops move no state; rejoins do
    for step in range(2, 8):
        ctl.apply_chaos(eng.step(step))
    rp = ctl.last_reshard
    assert rp.rejoined == (2,) and rp.dp_size == 4
    assert rp.transfer_bytes == 4 * ctl.stage_param_bytes()
    assert rp.source == "peer"


def test_heal_injector_drops_and_rejoins():
    eng = ChaosEngine(
        4, 4, 1.0,
        [DomainOutageWithHealInjector(3.0, 5.0, transfer_steps=1)],
        seed=3,
    )
    assert eng.elastic  # auto-enabled by the injector
    dropped, rejoined = set(), 0
    for step in range(200):
        out = eng.step(step)
        dropped |= set(out.plan.detached)
        rejoined += sum(1 for e in out.events if e.kind == RANK_REJOIN)
    assert dropped and rejoined > 0
    # every outage eventually healed: at most the in-flight domains remain
    assert len(eng.state.failed_until) <= 4


def test_non_elastic_engine_never_detaches():
    """Without elastic mode, a full-rank outage stays a transient failure:
    no membership change, no rejoin events (back-compat with old traces)."""
    eng = ChaosEngine(2, 2, 1.0, seed=0)  # elastic off
    for s in range(2):
        eng.schedule(FailureEvent(1, FAIL, (0, s), duration_steps=3))
    for step in range(8):
        out = eng.step(step)
        assert not out.plan.detached
    kinds = {e.kind for e in eng.events}
    assert RANK_REJOIN not in kinds
    assert RECOVER in kinds


@pytest.mark.chaos
def test_elastic_record_replay_bit_exact(tmp_path):
    """Elastic traces replay bit-exactly, including derived rejoin events
    and the rejoin transfer accounting."""
    path = tmp_path / "elastic.jsonl"
    eng = ChaosEngine(
        4, 4, 1.0,
        chaos_preset("elastic", FAST),
        seed=9, recorder=TraceRecorder(path),
    )
    ctl = _controller()
    _drive(eng, 150, ctl)
    eng.recorder.close(150, ctl.accounting.as_dict())
    assert ctl.accounting.n_rank_drops > 0 and ctl.accounting.n_rejoins > 0
    trace = load_trace(path)
    assert trace.header.elastic
    assert any(e.kind == RANK_REJOIN for e in trace.events)
    ctl2 = _controller()
    replayed = _drive(replay_engine(trace), 150, ctl2)
    problems = verify_replay(trace, replayed,
                             accounting=ctl2.accounting.as_dict())
    assert not problems, problems


@pytest.mark.chaos
def test_golden_elastic_trace_replays_bit_exactly():
    """The committed golden elastic trace reproduces events AND accounting
    (drop/heal/rejoin semantics are CI-pinned alongside the original trace)."""
    from pathlib import Path

    from repro.configs.base import get_config, reduced

    golden = Path(__file__).parent / "data" / "golden_trace_elastic.jsonl"
    trace = load_trace(golden)
    assert trace.footer is not None, "golden elastic trace missing footer"
    assert trace.header.elastic, "golden elastic trace not marked elastic"
    assert trace.footer.accounting["n_rank_drops"] > 0
    assert trace.footer.accounting["n_rejoins"] > 0
    cfg = reduced(get_config("llama-350m"), dtype="float32")
    ctl = FTController(
        cfg=cfg, mecefo=MeCeFOConfig(mode="dynamic"),
        n_dp=trace.header.n_dp, n_stages=trace.header.n_stages,
        global_batch=8,
    )
    engine = _drive(replay_engine(trace), trace.footer.total_steps, ctl)
    problems = verify_replay(trace, engine,
                             accounting=ctl.accounting.as_dict())
    assert not problems, problems


# ---------------------------------------------------------------------------
# trainer-level replay (slow: runs real jitted steps)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
@pytest.mark.slow
def test_trainer_record_then_replay_accounting(tmp_path):
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.launch.train import Trainer

    path = tmp_path / "trainer.jsonl"
    shape = ShapeConfig("t", 32, 4, "train")
    tc = TrainConfig(steps=25, learning_rate=3e-3)
    mecefo = MeCeFOConfig(mode="dynamic", rank=8, svd_period=10)
    rec = Trainer(
        TINY_DENSE, shape, tc, mecefo=mecefo,
        injectors=chaos_preset("kitchen-sink", SCENARIOS["high"]),
        n_dp=2, n_stages=2, step_time_s=3600.0, trace_record=str(path),
    )
    rec.run(log_every=0)
    rep = Trainer(
        TINY_DENSE, shape, tc, mecefo=mecefo, trace_replay=str(path),
    )
    rep.run(log_every=0)
    assert not rep.verify_replay()
    assert (
        rep.controller.accounting.as_dict()
        == rec.controller.accounting.as_dict()
    )
