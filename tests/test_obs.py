"""Unit tests for the obs telemetry subsystem (registry, spans, exporters).

Covers the contracts the rest of the repo leans on:

* instrument names validate against the catalog (no silent drift);
* counters are monotonic and integer adds stay integers (trace footers
  pin ints);
* the shared percentile helper keeps serve_bench's old ``_pctl``
  semantics (``None`` on an empty sample set, numpy values otherwise);
* the span tracer aggregates by nested stack path and survives
  exceptions without leaking the stack;
* the Prometheus exposition round-trips through the validator, and the
  validator rejects malformed pages;
* every stat key incremented in engine/router/controller source is
  declared in the catalog (the single-declaration satellite).
"""
import logging
import pathlib
import re

import numpy as np
import pytest

from repro import obs
from repro.obs.export import parse_prometheus_text, prometheus_text
from repro.obs.registry import MetricsRegistry
from repro.obs.spans import Tracer

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


# -- registry --------------------------------------------------------------

def test_counter_monotonic_and_int_preserving():
    reg = MetricsRegistry()
    c = reg.counter("train.steps_total")
    c.inc()
    c.inc(3)
    assert c.value == 4 and isinstance(c.value, int)
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value == 4


def test_undeclared_metric_name_raises():
    reg = MetricsRegistry()
    with pytest.raises(KeyError, match="not declared"):
        reg.counter("serve.engine.nope")


def test_wrong_kind_raises():
    reg = MetricsRegistry()
    with pytest.raises(TypeError):
        reg.histogram("train.steps_total")  # declared as a counter
    with pytest.raises(TypeError):
        reg.counter("serve.ttft_steps")  # declared as a histogram


def test_undeclared_label_raises():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="undeclared label"):
        reg.counter("kernels.impl_calls", labels={"kernel": "x", "bogus": "y"})


def test_same_name_instruments_aggregate_to_one_series():
    reg = MetricsRegistry()
    a = reg.counter("train.steps_total")
    b = reg.counter("train.steps_total")
    a.inc(2)
    b.inc(5)
    agg = reg.aggregate()
    assert agg[("train.steps_total", ())]["value"] == 7
    # ...but each holder still reads its own exact value
    assert (a.value, b.value) == (2, 5)


def test_labeled_series_stay_separate():
    reg = MetricsRegistry()
    x = reg.counter("kernels.impl_calls", labels={"kernel": "d", "impl": "xla"})
    y = reg.counter("kernels.impl_calls",
                    labels={"kernel": "d", "impl": "pallas"})
    x.inc(1)
    y.inc(2)
    flat = reg.snapshot()
    assert flat["kernels.impl_calls{impl=xla,kernel=d}"] == 1
    assert flat["kernels.impl_calls{impl=pallas,kernel=d}"] == 2


def test_histogram_buckets_and_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("serve.ttft_steps")
    for v in (1, 2, 3, 100):
        h.observe(v)
    assert h.count == 4
    assert sum(h.bucket_counts) == 4
    assert h.percentile(50) == float(np.percentile([1, 2, 3, 100], 50))
    # bucket ladder is the declared one, +Inf bucket implicit at the end
    assert h.buckets == obs.catalog.TOKEN_STEP_BUCKETS
    big = reg.histogram("serve.ttft_steps")
    big.observe(10_000)  # beyond the last declared bound -> +Inf bucket
    assert big.bucket_counts[-1] == 1


def test_snapshot_delta():
    reg = MetricsRegistry()
    c = reg.counter("train.steps_total")
    c.inc(2)
    before = reg.snapshot()
    c.inc(3)
    assert reg.delta(before) == {"train.steps_total": 3}
    assert reg.delta(reg.snapshot()) == {}


def test_percentile_matches_numpy_and_none_on_empty():
    assert obs.percentile([], 50) is None
    xs = [3.0, 1.0, 4.0, 1.5]
    for q in (50, 95, 99):
        assert obs.percentile(xs, q) == float(
            np.percentile(np.asarray(xs, np.float64), q)
        )
    assert isinstance(obs.percentile(xs, 50), float)


# -- spans -----------------------------------------------------------------

def test_span_nesting_aggregates_by_stack_path():
    tr = Tracer()
    for _ in range(3):
        with tr.span("router.step"):
            with tr.span("engine.decode_round"):
                pass
    with tr.span("engine.decode_round"):
        pass
    rows = {path: count for path, count, _ in tr.timeline()}
    assert rows["router.step"] == 3
    assert rows["router.step/engine.decode_round"] == 3
    assert rows["engine.decode_round"] == 1


def test_span_undeclared_name_raises():
    tr = Tracer()
    with pytest.raises(KeyError, match="not declared"):
        with tr.span("engine.bogus"):
            pass


def test_span_disabled_records_nothing():
    tr = Tracer()
    tr.enabled = False
    with tr.span("router.step"):
        pass
    assert tr.timeline() == []


def test_span_exception_does_not_leak_stack():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("router.step"):
            raise RuntimeError("boom")
    with tr.span("engine.prefill"):
        pass
    paths = [p for p, _, _ in tr.timeline()]
    # the second span must NOT appear nested under the failed first one
    assert "engine.prefill" in paths
    assert "router.step/engine.prefill" not in paths


# -- exporters -------------------------------------------------------------

def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("train.steps_total").inc(7)
    reg.counter("kernels.impl_calls",
                labels={"kernel": "decode", "impl": "xla"}).inc(2)
    h = reg.histogram("train.step.wall_s")
    for v in (0.002, 0.02, 0.2, 20.0):
        h.observe(v)
    return reg


def test_prometheus_round_trip():
    reg = _populated_registry()
    page = prometheus_text(reg)
    fams = parse_prometheus_text(page)
    assert fams["train_steps_total"]["type"] == "counter"
    assert fams["train_steps_total"]["samples"][0]["value"] == 7
    assert fams["kernels_impl_calls"]["samples"][0]["labels"] == {
        "kernel": "decode", "impl": "xla",
    }
    hist = fams["train_step_wall_s"]
    assert hist["type"] == "histogram"
    names = {s["name"] for s in hist["samples"]}
    assert {"train_step_wall_s_sum", "train_step_wall_s_count"} <= names
    # cumulative buckets: the +Inf bucket equals the count
    inf = [s for s in hist["samples"]
           if s["labels"].get("le") == "+Inf"]
    count = [s for s in hist["samples"]
             if s["name"] == "train_step_wall_s_count"]
    assert inf[0]["value"] == count[0]["value"] == 4


@pytest.mark.parametrize("page", [
    "what even is this\n",
    "# TYPE foo counter\n# TYPE foo counter\nfoo 1\n",   # duplicate TYPE
    "foo 1\n",                                           # no TYPE header
    "# TYPE foo counter\nfoo 1\nfoo 1\n",                # duplicate series
    "# TYPE foo counter\n",                              # header, no samples
    "# TYPE foo flavor\nfoo 1\n",                        # bad type
])
def test_prometheus_validator_rejects_malformed(page):
    with pytest.raises(ValueError):
        parse_prometheus_text(page)


def test_dump_report_and_prom_sibling(tmp_path):
    reg = _populated_registry()
    tr = Tracer()
    with tr.span("trainer.step"):
        with tr.span("controller.apply_chaos"):
            pass
    out = tmp_path / "run.jsonl"
    path = obs.dump(out, reg=reg, tracer=tr, meta={"run": "unit"})
    recs = obs.load_dump(path)
    assert recs[0]["type"] == "meta" and recs[0]["run"] == "unit"
    kinds = {r["type"] for r in recs}
    assert kinds == {"meta", "metric", "span"}
    hist = next(r for r in recs if r.get("name") == "train.step.wall_s")
    assert hist["count"] == 4 and hist["p50"] is not None
    # the .prom sibling exists and validates
    prom = path.with_suffix(path.suffix + ".prom")
    parse_prometheus_text(prom.read_text())
    # the report renders the span tree and the step-time section
    report = obs.render_report_file(path)
    assert "== obs report: unit ==" in report
    assert "train.step.wall_s" in report
    assert "controller.apply_chaos" in report


def test_report_cli(tmp_path, capsys):
    from repro.obs.report import main

    reg = _populated_registry()
    path = obs.dump(tmp_path / "run.jsonl", reg=reg, tracer=Tracer(),
                    meta={"run": "cli"})
    assert main(["report", str(path)]) == 0
    assert "obs report: cli" in capsys.readouterr().out
    assert main(["prom", str(path)]) == 0
    assert "train_steps_total" in capsys.readouterr().out


# -- every incremented stat key is declared (single-declaration pin) -------

def test_engine_stat_increments_are_declared():
    src = (SRC / "serve" / "engine.py").read_text()
    keys = set(re.findall(r'self\.stats\["(\w+)"\]', src))
    assert keys, "engine stats increments not found — did the pattern move?"
    undeclared = keys - set(obs.ENGINE_STAT_KEYS)
    assert not undeclared, f"undeclared engine stat keys: {sorted(undeclared)}"


def test_router_acct_increments_are_declared():
    src = (SRC / "serve" / "replicas.py").read_text()
    keys = set(re.findall(r'self\.acct\["(\w+)"\]', src))
    assert keys, "router acct increments not found — did the pattern move?"
    undeclared = keys - set(obs.ROUTER_ACCT_KEYS)
    assert not undeclared, f"undeclared router acct keys: {sorted(undeclared)}"


def test_recovery_accounting_writes_are_declared():
    src = (SRC / "ft" / "controller.py").read_text()
    keys = set(re.findall(r"self\.accounting\.(\w+)\s*\+?=", src))
    assert keys, "accounting writes not found — did the pattern move?"
    undeclared = keys - set(obs.FT_ACCOUNTING_KEYS)
    assert not undeclared, f"undeclared accounting fields: {sorted(undeclared)}"


def test_engine_stats_key_set_is_the_catalog_one():
    """The runtime key set (not just the source text) matches the catalog."""
    from repro.serve.engine import ServeEngine

    # ServeEngine.__init__ builds stats from obs.ENGINE_STAT_KEYS; pin the
    # class-level contract without constructing a full engine
    assert ServeEngine is not None
    assert set(obs.ROUTER_ACCT_KEYS) == (
        set(obs.catalog.ROUTER_ONLY_KEYS)
        | set(obs.ENGINE_STAT_KEYS)
        | set(obs.ALLOC_STAT_KEYS)
    )


# -- logging helper --------------------------------------------------------

def test_logging_setup_idempotent():
    obs.logging_setup(force=True)
    obs.logging_setup()
    obs.logging_setup()
    root = logging.getLogger("repro")
    assert len(root.handlers) == 1
    assert root.propagate is False
