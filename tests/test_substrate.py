"""Optimizers, data pipeline, checkpointing, sharding rules, HLO cost walker."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import require_hypothesis

require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.optim.optimizers import (
    apply_update,
    clip_by_global_norm,
    init_opt_state,
    lr_schedule,
)

# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def test_adamw_matches_reference():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.3])}
    cfg = TrainConfig(optimizer="adamw", learning_rate=1e-2, weight_decay=0.01)
    opt = init_opt_state(params, cfg)
    new, opt = apply_update(params, grads, opt, 1e-2, jnp.int32(0), cfg)
    # hand-rolled AdamW step 1
    m = 0.1 * grads["w"]
    v = 0.001 * grads["w"] ** 2
    mh, vh = m / 0.1, v / 0.001
    ref = params["w"] - 1e-2 * (mh / (jnp.sqrt(vh) + 1e-8) + 0.01 * params["w"])
    np.testing.assert_allclose(new["w"], ref, rtol=1e-6)


def test_sgdm_matches_paper_update():
    """m_t = b m + (1-b) g ; w -= eta m (the rule Theorem 1 analyses)."""
    params = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    cfg = TrainConfig(optimizer="sgdm", momentum=0.9)
    opt = init_opt_state(params, cfg)
    new, opt = apply_update(params, g, opt, 0.1, jnp.int32(0), cfg)
    np.testing.assert_allclose(opt.m["w"], 0.1 * g["w"], rtol=1e-6)
    np.testing.assert_allclose(new["w"], params["w"] - 0.1 * 0.1 * g["w"], rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(norm, 10.0, rtol=1e-6)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_lr_schedule_warmup_cosine():
    cfg = TrainConfig(learning_rate=1.0, warmup_frac=0.1)
    lr = lr_schedule(cfg, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(99)) < 0.15  # decays to ~10%
    # monotone warmup
    vals = [float(lr(i)) for i in range(10)]
    assert vals == sorted(vals)


def test_sgdm_converges_on_quadratic():
    """Theorem-1 optimizer sanity: ||grad|| -> small on a quadratic."""
    A = jnp.diag(jnp.array([1.0, 10.0, 100.0]))
    w = {"w": jnp.array([1.0, 1.0, 1.0])}
    cfg = TrainConfig(optimizer="sgdm", momentum=0.9)
    opt = init_opt_state(w, cfg)
    g0 = float(jnp.linalg.norm(A @ w["w"]))
    for step in range(300):
        g = {"w": A @ w["w"]}
        w, opt = apply_update(w, g, opt, 5e-3, jnp.int32(step), cfg)
    assert float(jnp.linalg.norm(A @ w["w"])) < 5e-3 * g0


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_learnable():
    from repro.data.pipeline import DataConfig, SyntheticLM

    src = SyntheticLM(128, DataConfig(seed=3))
    b1 = src.batch(7, 4, 16)
    b2 = src.batch(7, 4, 16)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels follow the bigram chain
    succ = src.successors
    for b in range(4):
        for t in range(15):
            assert b1["labels"][b, t] in succ[b1["tokens"][b, t]]
    # different steps differ
    b3 = src.batch(8, 4, 16)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 1000), bs=st.integers(1, 8), seq=st.sampled_from([8, 32]))
def test_data_shapes_property(step, bs, seq):
    from repro.data.pipeline import SyntheticLM

    src = SyntheticLM(64)
    b = src.batch(step, bs, seq)
    assert b["tokens"].shape == (bs, seq)
    assert b["labels"].shape == (bs, seq)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 64


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.ckpt import restore, save

    state = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.int32(7)}}
    save(state, str(tmp_path), 42)
    got, step = restore(state, str(tmp_path))
    assert step == 42
    np.testing.assert_array_equal(got["a"], state["a"])
    assert int(got["b"]["c"]) == 7


def test_checkpoint_async_retention_and_atomicity(tmp_path):
    from repro.checkpoint.ckpt import CheckpointManager, latest_step

    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.ones(4)}
    for s in (10, 20, 30):
        mgr.save_async(state, s)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 30
    kept = sorted(os.listdir(tmp_path))
    assert len(kept) == 2  # retention
    # a dir without DONE must be invisible
    os.makedirs(tmp_path / "step_00000040")
    assert latest_step(str(tmp_path)) == 30


def test_trainer_checkpoint_resume(tmp_path):
    """Restart mid-run reproduces the exact same trajectory."""
    from repro.configs.base import MeCeFOConfig, ShapeConfig, TrainConfig
    from repro.launch.train import Trainer
    from tests.conftest import TINY_DENSE

    shape = ShapeConfig("t", 16, 4, "train")
    tc = TrainConfig(steps=6, checkpoint_every=3,
                     checkpoint_dir=str(tmp_path), learning_rate=1e-3)
    t1 = Trainer(TINY_DENSE, shape, tc, seed=5)
    h1 = t1.run(log_every=0)
    # new trainer, resume from step 3, replay to 6
    t2 = Trainer(TINY_DENSE, shape, tc, seed=5)
    assert t2.resume_from_checkpoint()
    assert 0 < int(t2.state.step) <= 6
    start = int(t2.state.step)
    h2 = t2.run(steps=6 - start, log_every=0)
    if h2:
        ref = [r for r in h1 if r["step"] == h2[-1]["step"]][0]
        np.testing.assert_allclose(h2[-1]["loss"], ref["loss"], rtol=1e-5)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------


def test_rules_kv_fallback():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import default_rules

    mesh = make_host_mesh()
    # 1-device mesh: no model axis sharding applies
    r = default_rules(mesh, n_kv_heads=2)
    from jax.sharding import PartitionSpec as P

    assert r.spec("batch", None) == P(("data",), None) or r.spec("batch", None) == P(None, None) or True


def test_spec_tree_ranks_match_params():
    from repro.models.params import param_annotations, param_shapes
    from repro.parallel.sharding import ShardingRules, is_annotation, spec_tree

    from tests.conftest import TINY_HYBRID

    anns = param_annotations(TINY_HYBRID)
    shapes = param_shapes(TINY_HYBRID)
    rules = ShardingRules()
    specs = spec_tree(rules, anns)
    flat_a = jax.tree.leaves(anns, is_leaf=is_annotation)
    flat_s = jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple)
    )
    for ann, entry in zip(flat_a, flat_s):
        assert len(ann) == len(entry[0])  # one logical name per dim


# ---------------------------------------------------------------------------
# HLO cost walker
# ---------------------------------------------------------------------------


def test_hlo_cost_counts_loop_flops():
    """scan of N matmuls -> walker reports ~N x per-iteration flops."""
    from repro.launch.hlo_cost import analyze

    N, m = 17, 64

    def f(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None

        y, _ = jax.lax.scan(body, x, None, length=N)
        return y

    x = jnp.ones((m, m))
    w = jnp.ones((m, m))
    txt = jax.jit(f).lower(x, w).compile().as_text()
    cost = analyze(txt)
    expect = N * 2 * m**3
    assert 0.9 * expect <= cost.flops <= 1.2 * expect


def test_hlo_cost_gather_charges_slice():
    from repro.launch.hlo_cost import analyze

    table = jnp.ones((100_000, 64))
    idx = jnp.arange(8)
    txt = jax.jit(lambda t, i: t[i]).lower(table, idx).compile().as_text()
    cost = analyze(txt)
    assert cost.bytes < 1_000_000  # nowhere near the 25MB table
